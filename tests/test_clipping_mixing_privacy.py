"""Unit + property tests for clipping (Def. 2 / Remark 1), mixing matrices
(Def. 1) and the privacy accountant (Theorem 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import clipping as CL
from repro.core import mixing as MX
from repro.core import privacy as PV


# ---------------------------------------------------------------------------
# clipping
# ---------------------------------------------------------------------------

@given(st.integers(1, 500), st.integers(0, 10**6),
       st.floats(0.1, 10.0))
@settings(max_examples=30, deadline=None)
def test_smooth_clip_strict_bound(d, seed, tau):
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (d,)) * 10
    y = CL.smooth_clip(x, tau)
    assert float(jnp.linalg.norm(y)) < tau + 1e-5  # strictly inside the ball


@given(st.integers(1, 500), st.integers(0, 10**6), st.floats(0.1, 10.0))
@settings(max_examples=30, deadline=None)
def test_piecewise_clip_bound_and_identity(d, seed, tau):
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (d,))
    y = CL.piecewise_clip(x, tau)
    assert float(jnp.linalg.norm(y)) <= tau * (1 + 1e-5)
    if float(jnp.linalg.norm(x)) <= tau:
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)


def test_clip_direction_preserved():
    x = jnp.asarray([3.0, 4.0])
    for mode in ("smooth", "piecewise"):
        y = CL.tree_clip({"a": x}, 1.0, mode)["a"]
        cos = float(jnp.dot(x, y) / (jnp.linalg.norm(x) * jnp.linalg.norm(y)))
        assert cos > 1 - 1e-6


def test_clipped_grad_accumulate_matches_manual():
    def loss(p, batch):
        x, y = batch
        pred = x @ p["w"]
        return jnp.mean((pred - y) ** 2)

    k = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(k, (5,))}
    xb = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    yb = jax.random.normal(jax.random.PRNGKey(2), (7,))
    g, _ = CL.clipped_grad_accumulate(loss, p, (xb, yb), tau=0.5)
    manual = jnp.zeros(5)
    for i in range(7):
        gi = jax.grad(loss)(p, (xb[i:i + 1], yb[i:i + 1]))["w"]
        manual = manual + CL.smooth_clip(gi, 0.5)
    np.testing.assert_allclose(np.asarray(g["w"]), np.asarray(manual / 7),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# mixing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ring", "torus", "erdos_renyi", "complete",
                                  "star"])
@pytest.mark.parametrize("weights", ["metropolis", "best_constant", "lazy"])
def test_mixing_matrix_definition1(kind, weights):
    top = MX.make_topology(kind, 12, weights=weights, seed=3)
    w = top.w
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9)
    # graph constraint: w_ij = 0 when not connected (off-diagonal)
    off = ~np.eye(12, dtype=bool)
    disconnected = (top.adjacency == 0) & off
    assert np.all(np.abs(w[disconnected]) < 1e-12)
    assert 0.0 <= top.alpha < 1.0  # connected graph mixes


def test_better_connectivity_smaller_alpha():
    ring = MX.make_topology("ring", 16)
    er = MX.make_topology("erdos_renyi", 16, p=0.8, seed=0)
    comp = MX.make_topology("complete", 16)
    assert comp.alpha < er.alpha < ring.alpha
    assert comp.alpha < 1e-9  # complete + metropolis = exact averaging


def test_best_constant_beats_metropolis_on_ring():
    m = MX.make_topology("ring", 16, weights="metropolis")
    b = MX.make_topology("ring", 16, weights="best_constant")
    assert b.alpha <= m.alpha + 1e-12


def test_ring_detection():
    assert MX.make_topology("ring", 8).is_banded_ring()
    assert not MX.make_topology("erdos_renyi", 8, seed=1).is_banded_ring()


def test_mixing_contracts_disagreement():
    """One gossip step contracts ||X - xbar|| by at least alpha."""
    top = MX.make_topology("erdos_renyi", 10, p=0.8, seed=1)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(10, 7))
    xbar = x.mean(0, keepdims=True)
    mixed = top.w @ x
    num = np.linalg.norm(mixed - mixed.mean(0, keepdims=True))
    den = np.linalg.norm(x - xbar)
    assert num <= top.alpha * den + 1e-9


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------

def test_sigma_calibration_eq5():
    tau, T, m, eps, delta = 1.0, 10000, 3000, 0.1, 1e-3
    sigma = PV.calibrate_sigma(tau, T, m, eps, delta)
    # Eq. (5): sigma^2 = T tau^2 log(1/delta) / (m eps)^2
    np.testing.assert_allclose(
        sigma ** 2, T * tau ** 2 * np.log(1 / delta) / (m * eps) ** 2,
        rtol=1e-12)
    # equivalently T tau^2 phi_m^2 / d
    d = 123
    phi = PV.phi_m(d, m, eps, delta)
    np.testing.assert_allclose(sigma ** 2, T * tau ** 2 * phi ** 2 / d,
                               rtol=1e-12)


def test_accountant_monotonicity():
    base = dict(tau=1.0, T=2000, m=3000, delta=1e-3)
    e1 = PV.ldp_epsilon(sigma_p=PV.calibrate_sigma(1.0, 2000, 3000, 0.1, 1e-3),
                        **base)
    e2 = PV.ldp_epsilon(sigma_p=2 * PV.calibrate_sigma(1.0, 2000, 3000, 0.1,
                                                       1e-3), **base)
    assert e2 < e1  # more noise, more privacy
    e3 = PV.ldp_epsilon(
        sigma_p=PV.calibrate_sigma(1.0, 2000, 3000, 0.1, 1e-3),
        tau=1.0, T=4000, m=3000, delta=1e-3)
    assert e3 > e1  # more steps leak more


def test_theorem1_sigma_achieves_target_order():
    """Theorem-1 noise gives eps' = O(eps) under the moments accountant."""
    tau, m, delta = 1.0, 5000, 1e-3
    for eps in (0.05, 0.1, 0.5):
        T = 20000
        sigma = PV.calibrate_sigma(tau, T, m, eps, delta)
        eps_acct = PV.ldp_epsilon(tau, sigma, T, m, delta)
        assert eps_acct <= 4.0 * eps  # within the theorem's constant factor


def test_accountant_delta_inverse():
    acct = PV.MomentsAccountant(q=1e-3, noise_multiplier=4.0)
    acct.step(1000)
    eps = acct.epsilon(1e-5)
    assert acct.delta(eps) <= 1e-5 * 1.01


@given(st.floats(1e-4, 1e-2), st.floats(0.5, 8.0), st.integers(10, 20000),
       st.floats(1e-8, 1e-3))
@settings(max_examples=40, deadline=None)
def test_accountant_eps_delta_round_trip(q, s, steps, delta):
    """Round trip: both converters minimize over the same lambda grid, so
    delta(epsilon(delta)) <= delta and epsilon(delta(eps)) <= eps -- the
    tail bound never *loses* privacy through a conversion."""
    acct = PV.MomentsAccountant(q=q, noise_multiplier=s)
    acct.step(steps)
    eps = acct.epsilon(delta)
    assert np.isfinite(eps) and eps > 0
    d_back = acct.delta(eps)
    assert d_back <= delta * (1 + 1e-9)
    # and the reverse leg re-enters consistently
    assert acct.epsilon(d_back) <= eps * (1 + 1e-9)


@given(st.floats(0.05, 2.0), st.floats(1e-4, 1e-2), st.floats(1.0, 8.0),
       st.integers(10, 20000))
@settings(max_examples=40, deadline=None)
def test_accountant_delta_eps_round_trip(eps, q, s, steps):
    acct = PV.MomentsAccountant(q=q, noise_multiplier=s)
    acct.step(steps)
    d = acct.delta(eps)
    assert 0.0 < d <= 1.0
    if d >= 1.0:       # vacuous region: the bound says nothing at this eps
        return
    assert acct.epsilon(d) <= eps * (1 + 1e-9)


@given(st.floats(0.01, 2.0), st.floats(1.1, 10.0), st.integers(100, 50_000),
       st.floats(1.1, 10.0))
@settings(max_examples=40, deadline=None)
def test_calibrate_sigma_monotone_in_eps_and_T(eps, k_eps, T, k_T):
    """Eq. (5) sanity: a looser target (bigger eps) needs strictly less
    noise; more rounds (bigger T) need strictly more."""
    tau, m, delta = 1.0, 3000, 1e-3
    s0 = PV.calibrate_sigma(tau, T, m, eps, delta)
    assert PV.calibrate_sigma(tau, T, m, k_eps * eps, delta) < s0
    assert PV.calibrate_sigma(tau, int(k_T * T) + 1, m, eps, delta) > s0
