"""Time-varying topology engine (repro.core.mixing.TopologySchedule).

* Mixing regressions: ``mixing_rate``/``spectral_gap`` agree with dense
  ``numpy.linalg.eigvals`` for every graph kind, and every
  ``mixing_matrix`` output is doubly stochastic (star/hypercube included).
* Schedule construction: every generator emits doubly stochastic rounds,
  window-union connectivity is enforced, churn rounds isolate offline
  agents as identity rows.
* Engine: the executors index the schedule table by the traced round; the
  comm-round engine mixes with W_t.
* Parity (acceptance): a period-1 schedule reproduces the static
  trajectory for ALL registered algorithms (atol 1e-5); resume mid-period
  continues the schedule via the checkpointed step counter (manifest
  round-trip); a churn schedule trains under chunking with a single
  executable per chunk size.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, build, build_engine, list_algorithms,
                       resolve_schedule)
from repro.core import mixing as MX
from repro.core.gossip import apply_mixer, make_dense_mixer, make_mixer
from repro.data import minibatch_source
from repro.launch.runtime import make_runner

N, D, M, B = 4, 16, 32, 3

ALL_KINDS = ["ring", "torus", "erdos_renyi", "complete", "star",
             "exponential", "hypercube"]


# ---------------------------------------------------------------------------
# mixing regressions (static path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("weights", ["metropolis", "best_constant", "lazy"])
def test_mixing_matrix_doubly_stochastic_all_kinds(kind, weights):
    """Definition 1 for every (graph, weight) pair -- star and hypercube
    had no coverage before this file."""
    n = 8  # power of two: hypercube-compatible
    top = MX.make_topology(kind, n, weights=weights, seed=2)
    np.testing.assert_allclose(top.w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(top.w.sum(1), 1.0, atol=1e-9)
    off = ~np.eye(n, dtype=bool)
    assert np.all(np.abs(top.w[(top.adjacency == 0) & off]) < 1e-12)


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("weights", ["metropolis", "best_constant"])
def test_mixing_rate_matches_dense_eigvals(kind, weights):
    """alpha = ||W - J||_op must equal max |eig(W - J)| from dense numpy
    eigvals (W is symmetric for every weight scheme built here)."""
    top = MX.make_topology(kind, 8, weights=weights, seed=2)
    assert np.allclose(top.w, top.w.T, atol=1e-12)
    j = np.ones((8, 8)) / 8
    lam = np.max(np.abs(np.linalg.eigvals(top.w - j)))
    np.testing.assert_allclose(MX.mixing_rate(top.w), lam, atol=1e-9)
    np.testing.assert_allclose(MX.spectral_gap(top.w), 1.0 - lam, atol=1e-9)
    np.testing.assert_allclose(top.spectral_gap, 1.0 - top.alpha, atol=0)


# ---------------------------------------------------------------------------
# schedule construction
# ---------------------------------------------------------------------------

def _schedules():
    return [
        MX.static_schedule(MX.make_topology("ring", 6)),
        MX.rotating_schedule(["ring", "star", "complete"], 6),
        MX.rotating_schedule(["ring/metropolis", "ring/lazy"], 6),
        MX.erdos_renyi_schedule(6, p=0.7, period=4, seed=1),
        MX.dropout_schedule(6, rate=0.3, period=6, base="ring", seed=0),
        MX.straggler_schedule(6, rate=0.4, period=6, base="erdos_renyi",
                              p=0.7, seed=2),
    ]


@pytest.mark.parametrize("idx", range(6))
def test_schedule_rounds_doubly_stochastic(idx):
    sched = _schedules()[idx]
    assert sched.ws.shape == (sched.period, sched.n, sched.n)
    for t, w in enumerate(sched.ws):
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9, err_msg=str(t))
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9, err_msg=str(t))
        off = ~np.eye(sched.n, dtype=bool)
        assert np.all(
            np.abs(w[(sched.adjacencies[t] == 0) & off]) < 1e-12)
    # the window mixes even when individual rounds do not
    assert 0.0 <= sched.joint_alpha < 1.0
    assert sched.joint_spectral_gap > 0.0
    assert len(sched.alphas) == sched.period


def test_static_schedule_alpha_exact():
    top = MX.make_topology("erdos_renyi", 8, seed=3)
    sched = MX.static_schedule(top)
    assert sched.period == 1
    assert sched.alpha == top.alpha          # bit-exact, not just close
    assert sched.spectral_gap == top.spectral_gap
    np.testing.assert_array_equal(sched.ws[0], top.w)


def test_joint_alpha_submultiplicative():
    sched = MX.rotating_schedule(["ring", "complete", "star"], 8)
    assert sched.joint_alpha <= np.prod(sched.alphas) + 1e-9


def test_dropout_offline_agents_are_identity_rows():
    sched = MX.dropout_schedule(8, rate=0.4, period=6, base="ring", seed=0)
    isolated = [(t, i) for t in range(sched.period) for i in range(8)
                if sched.adjacencies[t][i].sum() == 0]
    assert isolated, "seed 0 at rate 0.4 must drop someone"
    for t, i in isolated:
        np.testing.assert_array_equal(sched.ws[t][i], np.eye(8)[i])
        np.testing.assert_array_equal(sched.ws[t][:, i], np.eye(8)[i])


def test_window_union_connectivity_enforced():
    # an agent that never talks within the window cannot reach consensus:
    # at rate 0.98 some agent is offline in every round of a short window
    # for (deterministically seeded) certain
    with pytest.raises(RuntimeError, match="window-connected"):
        MX.dropout_schedule(6, rate=0.98, period=1, seed=0)


def test_ring_schedule_stays_banded():
    sched = MX.rotating_schedule(["ring/metropolis", "ring/lazy"], 6)
    assert sched.is_banded_ring()
    er = MX.erdos_renyi_schedule(6, p=0.9, period=3, seed=4)
    assert not er.is_banded_ring()
    with pytest.raises(ValueError, match="ring"):
        make_mixer(er, "ring", mesh=object())
    # a pruned ring keeps the band but loses the circulant structure the
    # two-ppermute executor needs; the band-weight extraction rejects it
    churn = MX.dropout_schedule(6, rate=0.3, period=6, base="ring", seed=0)
    assert churn.is_banded_ring()
    with pytest.raises(ValueError, match="circulant"):
        make_mixer(churn, "ring", mesh=object())


def test_churn_rejects_best_constant_weights():
    with pytest.raises(ValueError, match="best_constant"):
        MX.dropout_schedule(6, rate=0.2, weights="best_constant")


def test_schedule_spec_parsing():
    spec = ExperimentSpec(n_agents=6, topology="ring")
    assert resolve_schedule(spec) is None
    s = resolve_schedule(spec.replace(topology_schedule="static"))
    assert s.period == 1
    s = resolve_schedule(
        spec.replace(topology_schedule="rotate:ring+star+complete"))
    assert s.period == 3
    # bare kinds compose with key=value knobs
    s = resolve_schedule(
        spec.replace(topology_schedule="rotate:ring+star,weights=lazy"))
    assert s.period == 2
    assert np.diag(s.ws[0]).min() >= 0.5 - 1e-12  # lazy: W = (I + W_m)/2
    s = resolve_schedule(
        spec.replace(topology_schedule="rotate:kinds=ring+star,seed=3"))
    assert s.period == 2
    s = resolve_schedule(
        spec.replace(topology_schedule="erdos_renyi:period=3,p=0.7"))
    assert s.period == 3
    s = resolve_schedule(
        spec.replace(topology_schedule="dropout:rate=0.3,period=5"))
    assert s.period == 5 and "rate=0.3" in s.kind
    s = resolve_schedule(
        spec.replace(topology_schedule="straggler:rate=0.2,period=4,"
                                       "base=complete"))
    assert s.period == 4 and "base=complete" in s.kind
    # directed (column-stochastic) family: 'directed:<subkind>,key=value'
    s = resolve_schedule(
        spec.replace(topology_schedule="directed:ring_skips,skip=2"))
    assert s.is_directed and s.period == 1 and s.stochasticity == "column"
    s = resolve_schedule(
        spec.replace(topology_schedule="directed:one_way,rate=0.2,period=4"))
    assert s.period == 4 and s.stochasticity == "column"
    with pytest.raises(ValueError, match="unknown topology schedule"):
        resolve_schedule(spec.replace(topology_schedule="warp:speed=9"))
    with pytest.raises(ValueError, match="unknown 'dropout' schedule keys"):
        resolve_schedule(spec.replace(topology_schedule="dropout:rte=0.3"))
    with pytest.raises(ValueError, match="key=value"):
        resolve_schedule(spec.replace(topology_schedule="dropout:0.3"))
    with pytest.raises(ValueError, match="unknown directed schedule subkind"):
        resolve_schedule(spec.replace(topology_schedule="directed:spiral"))
    with pytest.raises(ValueError, match="directed:one_way schedule keys"):
        resolve_schedule(
            spec.replace(topology_schedule="directed:one_way,rte=0.2"))


# ---------------------------------------------------------------------------
# generator property sweep (completeness-checked against the registry)
# ---------------------------------------------------------------------------

# one representative build per registered generator; the completeness test
# below fails when a new generator lands without a row here
_GEN_CASES = {
    "rotate": lambda: MX.rotating_schedule(["ring", "star", "complete"], 6),
    "erdos_renyi": lambda: MX.erdos_renyi_schedule(6, p=0.7, period=4,
                                                   seed=1),
    "dropout": lambda: MX.dropout_schedule(6, rate=0.3, period=6,
                                           base="ring", seed=0),
    "straggler": lambda: MX.straggler_schedule(6, rate=0.4, period=6,
                                               base="erdos_renyi", p=0.7,
                                               seed=2),
    "ring_skips": lambda: MX.directed_ring_schedule(6, skip=2),
    "digraph": lambda: MX.random_digraph_schedule(6, p=0.5, period=4,
                                                  seed=3),
    "one_way": lambda: MX.directed_churn_schedule(6, rate=0.3, period=4,
                                                  skip=2, seed=0),
}


def test_generator_sweep_is_complete():
    """Every registered generator has a property-sweep case, and the
    stochasticity registry backs exactly the dispatch table."""
    assert set(_GEN_CASES) == set(MX.SCHEDULE_STOCHASTICITY)
    assert set(MX.SCHEDULE_STOCHASTICITY) == set(MX._SCHEDULE_GENERATORS)
    assert set(MX.SCHEDULE_STOCHASTICITY.values()) == {"doubly", "column"}


def _slem(w):
    """Second-largest eigenvalue modulus (Perron root excluded) -- the
    dense-eigvals oracle for stochastic matrices."""
    ev = np.linalg.eigvals(np.asarray(w, np.float64))
    return float(np.max(np.abs(np.delete(ev, np.argmin(np.abs(ev - 1.0))))))


@pytest.mark.parametrize("kind", sorted(_GEN_CASES))
def test_generator_stochasticity_and_contraction_oracle(kind):
    """Acceptance sweep: every generator's rounds carry the stochasticity
    the registry declares, and the recorded per-round/joint contraction
    factors agree with a dense ``numpy.linalg.eigvals`` oracle."""
    sched = _GEN_CASES[kind]()
    tag = MX.SCHEDULE_STOCHASTICITY[kind]
    assert sched.stochasticity == tag
    assert sched.is_directed == (tag == "column")
    n = sched.n
    j = np.ones((n, n)) / n
    for t, w in enumerate(sched.ws):
        # columns always sum to 1 (mass conservation: 1^T W = 1^T)
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9,
                                   err_msg=f"{kind} round {t} columns")
        if tag == "doubly":
            np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9,
                                       err_msg=f"{kind} round {t} rows")
            assert np.allclose(w, w.T, atol=1e-12)
            # symmetric W: ||W - J||_2 == max |eig(W - J)|
            oracle = float(np.max(np.abs(np.linalg.eigvals(w - j))))
        else:
            assert np.all(np.diag(w) > 0), f"{kind} round {t} diagonal"
            oracle = _slem(w)
        np.testing.assert_allclose(sched.alphas[t], oracle, atol=1e-9,
                                   err_msg=f"{kind} round {t} alpha")
    # joint window contraction against the same eigvals oracle
    prod = np.eye(n)
    if tag == "doubly":
        for w in sched.ws:
            prod = (w - j) @ prod
        # ||B||_2 == sqrt(max eig(B^T B))
        oracle = float(np.sqrt(np.max(np.abs(
            np.linalg.eigvals(prod.T @ prod)))))
    else:
        for w in sched.ws:
            prod = w @ prod
        oracle = _slem(prod)
    np.testing.assert_allclose(sched.joint_alpha, oracle, atol=1e-9,
                               err_msg=f"{kind} joint")
    assert 0.0 <= sched.joint_alpha < 1.0


def test_directed_generators_break_row_stochasticity():
    """The resampling directed generators must produce genuinely one-way
    rounds (row sums != 1) -- otherwise the column tag is vacuous and
    push-sum de-biasing is untested against them."""
    for kind in ("digraph", "one_way"):
        sched = _GEN_CASES[kind]()
        assert any(not np.allclose(w.sum(1), 1.0, atol=1e-6)
                   for w in sched.ws), kind


# ---------------------------------------------------------------------------
# executors index the table by the traced round
# ---------------------------------------------------------------------------

def test_dense_mixer_schedule_indexing():
    sched = MX.rotating_schedule(["complete", "ring"], 6)
    mixer = make_dense_mixer(sched.ws)
    assert mixer.time_varying
    tree = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(6, 5)),
                             jnp.float32)}
    for t in range(5):
        want = sched.ws[t % 2].astype(np.float32) @ np.asarray(tree["w"])
        got = apply_mixer(mixer, tree, jnp.asarray(t, jnp.int32))["w"]
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5,
                                   rtol=1e-5)
    # a jitted traced index hits the same entries (the in-program gather)
    jitted = jax.jit(lambda tr, t: mixer(tr, t))
    got = jitted(tree, jnp.asarray(3, jnp.int32))["w"]
    np.testing.assert_allclose(np.asarray(got),
                               sched.ws[1].astype(np.float32)
                               @ np.asarray(tree["w"]),
                               atol=1e-5, rtol=1e-5)
    with pytest.raises(ValueError, match="round index"):
        apply_mixer(mixer, tree, None)
    # static mixers ignore the index entirely
    static = make_dense_mixer(sched.ws[0])
    assert not static.time_varying
    np.testing.assert_allclose(
        np.asarray(apply_mixer(static, tree, 3)["w"]),
        np.asarray(apply_mixer(static, tree)["w"]))


def test_engine_exchange_mixes_with_round_matrix():
    sched = MX.rotating_schedule(["complete", "ring"], N)
    spec = ExperimentSpec(algo="porter-gc", n_agents=N, compressor="identity",
                          topology_schedule="rotate:complete+ring", gamma=0.1)
    eng = build_engine(spec, schedule=sched)
    y = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(N, 7)),
                          jnp.float32)}
    q = {"w": jnp.zeros((N, 7), jnp.float32)}
    for t in (0, 1, 2, 7):
        c, wc = eng.exchange(jax.random.PRNGKey(0), y, q,
                             jnp.asarray(t, jnp.int32))
        want = sched.ws[t % 2].astype(np.float32) @ np.asarray(y["w"])
        np.testing.assert_allclose(np.asarray(wc["w"]), want, atol=1e-5,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# trajectory parity + resume (the runtime-facing contract)
# ---------------------------------------------------------------------------

def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, M, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, minibatch_source(f, l, B)


def _spec(name, **kw):
    base = dict(algo=name, n_agents=N, topology="ring", compressor="top_k",
                frac=0.25, eta=0.1, tau=5.0,
                sigma_p=0.01 if name in ("porter-dp", "dp-sgd", "soteriafl")
                else 0.0)
    base.update(kw)
    return ExperimentSpec(**base)


def _per_step_loop(algo, source, state, key, steps, start=0):
    """Per-step loop with the runtime's key contract (split(fold_in(k, t)))."""
    step = jax.jit(algo.step)
    traj = []
    for t in range(start, start + steps):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        state, m = step(state, source(kb, jnp.asarray(t, jnp.int32)), ks)
        traj.append(m)
    return state, traj


@pytest.mark.parametrize("name", sorted(list_algorithms()))
def test_period1_schedule_matches_static_trajectory(name):
    """Acceptance: topology_schedule='static' (the period-1 wrapper through
    the time-varying engine) is trajectory-identical to the baked static
    path for every registered algorithm."""
    params0, source = _problem()
    ref = build(_spec(name), _loss_fn)
    got = build(_spec(name, topology_schedule="static"), _loss_fn)
    if ref.info.decentralized:
        assert got.schedule is not None and got.schedule.period == 1
        assert got.gamma == ref.gamma  # same alpha -> same derivation
    ref_state, ref_traj = _per_step_loop(
        ref, source, ref.init(params0), jax.random.PRNGKey(7), 5)
    got_state, got_traj = _per_step_loop(
        got, source, got.init(params0), jax.random.PRNGKey(7), 5)
    for rm, gm in zip(ref_traj, got_traj):
        for k in rm:
            np.testing.assert_allclose(np.asarray(gm[k]), np.asarray(rm[k]),
                                       atol=1e-5, rtol=1e-5,
                                       err_msg=f"{name}: metric {k!r}")
    for rl, gl in zip(jax.tree_util.tree_leaves(ref_state),
                      jax.tree_util.tree_leaves(got_state)):
        np.testing.assert_allclose(np.asarray(gl), np.asarray(rl),
                                   atol=1e-5, rtol=1e-5)


def test_schedule_actually_changes_the_trajectory():
    """Guard against a silently ignored round index: a rotating schedule
    must NOT reproduce the static path."""
    params0, source = _problem()
    ref = build(_spec("porter-gc"), _loss_fn)
    got = build(_spec("porter-gc",
                      topology_schedule="rotate:ring+complete"), _loss_fn)
    _, ref_traj = _per_step_loop(ref, source, ref.init(params0),
                                 jax.random.PRNGKey(7), 5)
    _, got_traj = _per_step_loop(got, source, got.init(params0),
                                 jax.random.PRNGKey(7), 5)
    assert not np.allclose([r["consensus_x"] for r in ref_traj],
                           [g["consensus_x"] for g in got_traj])


def test_resume_mid_period_continues_schedule(tmp_path):
    """Round t's W comes from the *checkpointed* step counter, so a
    restart mid-period picks the window up where it left off (and the
    manifest records which schedule the rounds ran under)."""
    from repro.launch.checkpoint import (read_manifest, restore_state,
                                         save_state)

    sched_str = "rotate:ring+complete+star"   # period 3; 4 rounds lands mid
    params0, source = _problem()
    spec = _spec("porter-gc", topology_schedule=sched_str)
    algo = build(spec, _loss_fn)

    ref_state, _ = _per_step_loop(algo, source, algo.init(params0),
                                  jax.random.PRNGKey(7), 8)

    runner = make_runner(algo, source, 4)
    state, _, _ = runner(algo.init(params0), jax.random.PRNGKey(7), 0)
    save_state(tmp_path, state, step=4,
               extra={"topology_schedule": sched_str})
    man = read_manifest(tmp_path)
    assert man["extra"]["topology_schedule"] == sched_str
    assert man["step"] == 4

    # a fresh process: rebuild from the same spec, restore, continue
    algo2 = build(spec, _loss_fn)
    restored = restore_state(tmp_path, like=algo2.init(params0))
    assert int(restored.step) == 4   # 4 mod 3 = 1: mid-window
    state2, _, _ = make_runner(algo2, source, 4)(
        restored, jax.random.PRNGKey(7), 4)
    for rl, gl in zip(jax.tree_util.tree_leaves(ref_state),
                      jax.tree_util.tree_leaves(state2)):
        np.testing.assert_allclose(np.asarray(gl), np.asarray(rl),
                                   atol=1e-5, rtol=1e-5)


def test_churn_schedule_single_executable_under_chunk():
    """Acceptance: a churn schedule trains under the scan-fused runtime
    with ONE executable per chunk size -- W_t is a traced gather, never a
    recompile."""
    params0, source = _problem()
    spec = _spec("porter-gc",
                 topology_schedule="dropout:rate=0.25,period=4")
    algo = build(spec, _loss_fn)
    runner = make_runner(algo, source, 4)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)
    losses = []
    for start in (0, 4, 8):   # crosses the period boundary twice
        state, key, m = runner(state, key, start)
        losses.extend(np.asarray(m["loss"]).tolist())
    assert runner.cache_size() in (None, 1)
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0]  # the smoke problem is easy


def test_dsgd_uncompressed_schedule_round_trip():
    """The uncompressed baseline threads the round index through
    apply_mixer (no engine): one gossip step with W_t must match numpy."""
    sched = MX.rotating_schedule(["complete", "ring"], N)
    spec = _spec("dsgd", topology_schedule="rotate:complete+ring",
                 tau=None, eta=0.0, gamma=1.0)
    algo = build(spec, _loss_fn)
    params0, source = _problem()
    state = algo.init(params0)
    x0 = np.asarray(state.x["w"])
    batch = source(jax.random.PRNGKey(0), jnp.asarray(0))
    state1, _ = jax.jit(algo.step)(state, batch, jax.random.PRNGKey(1))
    # eta=0, gamma=1: x1 = W_0 x0 exactly
    np.testing.assert_allclose(np.asarray(state1.x["w"]),
                               sched.ws[0].astype(np.float32) @ x0,
                               atol=1e-5, rtol=1e-5)
    state2, _ = jax.jit(algo.step)(state1, batch, jax.random.PRNGKey(2))
    np.testing.assert_allclose(
        np.asarray(state2.x["w"]),
        sched.ws[1].astype(np.float32) @ np.asarray(state1.x["w"]),
        atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Sparse (matrix-free) validators vs. the dense historical path.  The
# schedule finalizers switch to power/Lanczos contraction estimates and
# BFS union connectivity at n > MX.VALIDATE_DENSE_GATE; this regression
# pins that both paths agree on every registered generator well below the
# gate, so flipping it can never change a validation verdict.
# ---------------------------------------------------------------------------

_GEN_CASES_64 = {
    "rotate": lambda: MX.rotating_schedule(["ring", "exponential",
                                            "complete"], 64),
    "erdos_renyi": lambda: MX.erdos_renyi_schedule(64, p=0.15, period=4,
                                                   seed=1),
    "dropout": lambda: MX.dropout_schedule(64, rate=0.3, period=4,
                                           base="ring", seed=0),
    "straggler": lambda: MX.straggler_schedule(64, rate=0.4, period=4,
                                               base="erdos_renyi", p=0.15,
                                               seed=2),
    "ring_skips": lambda: MX.directed_ring_schedule(64, skip=5),
    "digraph": lambda: MX.random_digraph_schedule(64, p=0.08, period=4,
                                                  seed=3),
    "one_way": lambda: MX.directed_churn_schedule(64, rate=0.3, period=4,
                                                  skip=5, seed=0),
}


def test_sparse_validator_cases_cover_generators():
    assert set(_GEN_CASES_64) == set(MX._SCHEDULE_GENERATORS)


@pytest.mark.parametrize("kind", sorted(_GEN_CASES_64))
def test_sparse_validators_agree_with_dense(kind):
    """dense product/SVD vs. matrix-free Lanczos/Arnoldi, per round and
    over the joint window, plus the BFS union-connectivity verdict."""
    sched = _GEN_CASES_64[kind]()
    ws = [np.asarray(w, np.float64) for w in sched.ws]
    union = np.abs(np.stack(ws)).sum(axis=0)
    if sched.is_directed:
        dense_joint = MX.joint_window_contraction(ws, method="dense")
        power_joint = MX.joint_window_contraction(ws, method="power")
        per_dense = [MX.contraction_factor(w) for w in ws]
        per_power = [MX.joint_window_contraction([w], method="power")
                     for w in ws]
        dense_conn = MX._is_strongly_connected(union)
        sparse_conn = MX.union_connected(ws, directed=True)
    else:
        dense_joint = MX.joint_window_alpha(ws, method="dense")
        power_joint = MX.joint_window_alpha(ws, method="power")
        per_dense = [MX.mixing_rate(w) for w in ws]
        per_power = [MX.mixing_rate_power(w) for w in ws]
        dense_conn = MX._is_connected(union)
        sparse_conn = MX.union_connected(ws, directed=False)
    np.testing.assert_allclose(power_joint, dense_joint, rtol=1e-8,
                               atol=1e-10, err_msg=f"{kind} joint")
    np.testing.assert_allclose(per_power, per_dense, rtol=1e-8,
                               atol=1e-10, err_msg=f"{kind} per-round")
    assert sparse_conn == dense_conn is True, kind


def test_above_gate_schedule_takes_sparse_validators():
    """n > VALIDATE_DENSE_GATE finalizes through the matrix-free path and
    still produces a contracting, validated schedule."""
    n = MX.VALIDATE_DENSE_GATE + 44
    sched = MX.erdos_renyi_schedule(n, p=0.03, period=3, seed=4)
    assert 0.0 < sched.joint_alpha < 1.0
    # spot-check one round against the dense oracle anyway
    np.testing.assert_allclose(sched.alphas[0],
                               MX.mixing_rate(sched.ws[0]), rtol=1e-7)


def test_union_connected_detects_disconnection():
    a = np.zeros((6, 6))
    a[:3, :3] = np.eye(3) + np.roll(np.eye(3), 1, axis=1)
    a[3:, 3:] = np.eye(3) + np.roll(np.eye(3), 1, axis=1)
    assert not MX.union_connected([a], directed=False)
    assert not MX.union_connected([a], directed=True)
    b = a.copy()
    b[0, 3] = b[3, 0] = 1.0
    assert MX.union_connected([b], directed=False)
    assert MX.union_connected([b], directed=True)
