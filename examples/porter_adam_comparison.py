"""Beyond-paper: PORTER vs PORTER-Adam on the ill-conditioned MLP problem.

Same wire protocol (two compressed streams), same clipping, same graph --
the only change is Adam-preconditioning the *tracked* gradient locally.
On the badly-scaled MLP this typically reaches a given loss in fewer rounds.

    PYTHONPATH=src python examples/porter_adam_comparison.py
"""

import jax

from repro.api import ExperimentSpec, build
from repro.data import minibatch_source, mnist_like, shard_to_agents
from repro.launch.runtime import run_chunked
from repro.models import mlp_init, mlp_loss

N, STEPS = 8, 200

x, y = mnist_like(8000, seed=0)
xs, ys = shard_to_agents(x, y, N)

loss_fn = mlp_loss()            # the shared Section-5.2 MLP definition
params0 = mlp_init(jax.random.PRNGKey(0))
source = minibatch_source(xs, ys, batch=8)

base = ExperimentSpec(n_agents=N, topology="exponential",
                      compressor="top_k", frac=0.05, tau=5.0)

runs = {}
for name, spec in {
    "porter_gc": base.replace(algo="porter-gc", eta=0.2),
    "porter_adam": base.replace(algo="porter-adam", eta=0.02),
}.items():
    algo = build(spec, loss_fn)
    curve = []

    def sample(t0, t1, st, m):  # 20-round chunks: sync once per sample
        curve.append((t0, float(m["loss"][0])))
        if t1 == STEPS:
            curve.append((t1 - 1, float(m["loss"][-1])))

    run_chunked(algo, source, algo.init(params0), jax.random.PRNGKey(0),
                STEPS, chunk=20, on_chunk=sample)
    runs[name] = curve

print(f"{'round':>8s} {'porter_gc':>12s} {'porter_adam':>12s}")
for (t, a), (_, b) in zip(runs["porter_gc"], runs["porter_adam"]):
    print(f"{t:8d} {a:12.4f} {b:12.4f}")
print("\nSame communication (two top-5% streams/round); Adam preconditioning "
      "of the tracked gradient is a purely-local change (beyond-paper; see "
      "core/porter_adam.py for the caveat about theory coverage).")
