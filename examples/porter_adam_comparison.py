"""Beyond-paper: PORTER vs PORTER-Adam on the ill-conditioned MLP problem.

Same wire protocol (two compressed streams), same clipping, same graph --
the only change is Adam-preconditioning the *tracked* gradient locally.
On the badly-scaled MLP this typically reaches a given loss in fewer rounds.

    PYTHONPATH=src python examples/porter_adam_comparison.py
"""

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, build
from repro.data import agent_batch_iterator, mnist_like, shard_to_agents

N, STEPS = 8, 200

x, y = mnist_like(8000, seed=0)
xs, ys = shard_to_agents(x, y, N)


def loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
    logits = h @ params["w2"] + params["c2"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


k1, k2 = jax.random.split(jax.random.PRNGKey(0))
params0 = {"w1": 0.05 * jax.random.normal(k1, (784, 64)),
           "c1": jnp.zeros(64),
           "w2": 0.05 * jax.random.normal(k2, (64, 10)),
           "c2": jnp.zeros(10)}

base = ExperimentSpec(n_agents=N, topology="exponential",
                      compressor="top_k", frac=0.05, tau=5.0)

runs = {}
for name, spec in {
    "porter_gc": base.replace(algo="porter-gc", eta=0.2),
    "porter_adam": base.replace(algo="porter-adam", eta=0.02),
}.items():
    algo = build(spec, loss_fn)
    state = algo.init(params0)
    step = jax.jit(algo.step)
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    curve = []
    for t in range(STEPS):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
        if t % 20 == 0 or t == STEPS - 1:
            curve.append((t, float(m["loss"])))
    runs[name] = curve

print(f"{'round':>8s} {'porter_gc':>12s} {'porter_adam':>12s}")
for (t, a), (_, b) in zip(runs["porter_gc"], runs["porter_adam"]):
    print(f"{t:8d} {a:12.4f} {b:12.4f}")
print("\nSame communication (two top-5% streams/round); Adam preconditioning "
      "of the tracked gradient is a purely-local change (beyond-paper; see "
      "core/porter_adam.py for the caveat about theory coverage).")
