"""Quickstart: decentralized training through the ``repro.api`` facade.

Ten agents on an Erdos-Renyi graph minimize a nonconvex logistic-regression
objective with 5%-top-k compressed gossip and smooth gradient clipping --
exactly the paper's Section 5.1 protocol, on synthetic a9a-shaped data.

One ExperimentSpec names the whole experiment; ``build`` resolves the
topology, mixing matrix, compressor, comm-round engine and the consensus
stepsize gamma = 0.5 * (1 - alpha) * rho.  Swap ``algo="porter-gc"`` for any
registered name (porter-dp, beer, choco, dsgd, soteriafl, porter-adam,
dp-sgd) to train a different optimizer with the same three lines.

Training runs through the chunked runtime: ``run_chunked`` scan-fuses 50
comm rounds per compiled dispatch (donated state, batches synthesized on
device by ``minibatch_source``), so the host syncs once per printed line
instead of once per round.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.core import average_params
from repro.launch.runtime import run_chunked

N_AGENTS, RHO = 10, 0.05

# --- data: shuffled and split evenly across agents -------------------------
x, y = a9a_like(num=20000, dim=123, seed=0)
xs, ys = shard_to_agents(x, y, N_AGENTS)
batches = minibatch_source(xs, ys, batch=8)


# --- the objective (paper eq. in Section 5.1) -------------------------------
def loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
    return nll + 0.2 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))


# --- PORTER-GC over an ER(0.8) graph, declared then built -------------------
spec = ExperimentSpec(algo="porter-gc", n_agents=N_AGENTS,
                      topology="erdos_renyi", topology_weights="best_constant",
                      topology_p=0.8, topology_seed=1,
                      compressor="top_k", frac=RHO,
                      eta=0.05, tau=1.0)
algo = build(spec, loss_fn)

params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
state = algo.init(params0)


def report(t0, t1, st, metrics):  # one host sync per 50-round chunk
    print(f"step {t0:4d}  loss {float(metrics['loss'][0]):.4f}  "
          f"consensus {float(metrics['consensus_x'][0]):.2e}")


state, _ = run_chunked(algo, batches, state, jax.random.PRNGKey(0), 400,
                       chunk=50, on_chunk=report)

avg = average_params(state.x)
full = (jnp.asarray(xs.reshape(-1, 123)), jnp.asarray(ys.reshape(-1)))
g = jax.grad(loss_fn)(avg, full)
gn = float(np.sqrt(np.asarray(
    sum(jnp.sum(v ** 2) for v in jax.tree_util.tree_leaves(g)))))
print(f"\nfinal grad norm of the average iterate: {gn:.4f} "
      f"(alpha={algo.topology.alpha:.3f}, rho={RHO}, "
      f"gamma={algo.gamma:.4f})")
assert gn < 0.1
