"""End-to-end driver: train a transformer LM decentralized-and-privately.

Eight agents train a reduced TinyLlama-family model with PORTER-DP:
per-sample smooth clipping, Theorem-1-calibrated Gaussian perturbation for a
(0.5, 1e-3)-LDP target, top-5% compressed gossip over a ring.  This is the
"train a ~100M model for a few hundred steps" end-to-end example scaled to
the CPU container (pass --big on a real pod to use the full config).

    PYTHONPATH=src python examples/private_decentralized_lm.py --steps 120
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build
from repro.configs import get_config, get_smoke
from repro.core import calibrate_sigma, ldp_epsilon
from repro.data import batch_source
from repro.launch.runtime import run_chunked
from repro.models import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--chunk", type=int, default=20,
                help="comm rounds scan-fused per dispatch")
ap.add_argument("--agents", type=int, default=4)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--epsilon", type=float, default=0.5)
ap.add_argument("--delta", type=float, default=1e-3)
ap.add_argument("--samples-per-agent", type=int, default=8192)
ap.add_argument("--big", action="store_true", help="full tinyllama-1.1b")
args = ap.parse_args()

cfg = get_config("tinyllama-1.1b") if args.big else \
    dataclasses.replace(get_smoke("tinyllama-1.1b"), n_layers=2, d_model=128,
                        d_ff=352, n_heads=4, n_kv_heads=2, vocab=1024)
cfg = dataclasses.replace(cfg, remat=False)
bundle = build_model(cfg)
params, _ = bundle.init(jax.random.PRNGKey(0))
n_params = sum(int(np.prod(p.shape))
               for p in jax.tree_util.tree_leaves(params))

# --- privacy calibration (Theorem 1) ----------------------------------------
tau = 1.0
sigma_p = calibrate_sigma(tau, args.steps, args.samples_per_agent,
                          args.epsilon, args.delta)
eps_acct = ldp_epsilon(tau, sigma_p, args.steps, args.samples_per_agent,
                       args.delta, b=args.batch)
print(f"model: {n_params/1e6:.1f}M params | agents: {args.agents} | "
      f"sigma_p = {sigma_p:.4g} for ({args.epsilon},{args.delta})-LDP "
      f"(accountant says eps = {eps_acct:.3g})")

# --- PORTER-DP over a ring ----------------------------------------------------
spec = ExperimentSpec(algo="porter-dp", n_agents=args.agents,
                      topology="ring", compressor="top_k", frac=0.05,
                      eta=5e-2, tau=tau, sigma_p=sigma_p)
algo = build(spec, bundle.loss)
state = algo.init(params)
source = batch_source(cfg, args.agents, args.batch, args.seq)

t0 = time.time()
span = {"first": None, "last": None}


def report(ts, te, st, m):
    # one host sync per chunk; batches were synthesized on device
    loss = jax.device_get(m["loss"])
    if span["first"] is None:
        span["first"] = float(loss[0])
    span["last"] = float(loss[-1])
    for i, t in enumerate(range(ts, te)):
        if t % 20 == 0 or t == args.steps - 1:
            print(f"step {t:4d}  loss {float(loss[i]):.4f}  "
                  f"consensus {float(m['consensus_x'][i]):.2e}  "
                  f"({time.time()-t0:.1f}s)")


run_chunked(algo, source, state, jax.random.PRNGKey(1), args.steps,
            chunk=args.chunk, on_chunk=report)
first, last = span["first"], span["last"]

print(f"\nloss {first:.3f} -> {last:.3f}; every gradient an agent ever "
      f"shared was clipped to tau={tau} and perturbed: the run is "
      f"({args.epsilon},{args.delta})-LDP end to end.")
