"""Sweep the paper's trade-off surface: final utility vs privacy budget
(epsilon) and compression ratio (rho), reproducing the qualitative shape of
Theorems 2-4 on the logistic-regression testbed.

    PYTHONPATH=src python examples/privacy_compression_tradeoff.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build
from repro.core import average_params, calibrate_sigma, phi_m
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.launch.runtime import make_runner

N, D, STEPS = 10, 123, 250

x, y = a9a_like(20000, D, seed=0)
xs, ys = shard_to_agents(x, y, N)
m = xs.shape[1]

BASE = ExperimentSpec(n_agents=N, topology="erdos_renyi",
                      topology_weights="best_constant", topology_p=0.8,
                      topology_seed=1, eta=0.05, tau=1.0)


def loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
    return nll + 0.2 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))


def run_sweep(variant, rho, sigma_p):
    spec = BASE.replace(
        algo=f"porter-{variant}",
        compressor="top_k" if variant == "gc" else "random_k", frac=rho,
        sigma_p=sigma_p)
    algo = build(spec, loss_fn)
    state = algo.init({"w": jnp.zeros(D), "b": jnp.zeros(())})
    source = minibatch_source(xs, ys, batch=1 if variant == "dp" else 4)
    # the whole sweep point is ONE scan-fused dispatch (chunk = STEPS)
    runner = make_runner(algo, source, STEPS)
    state, _, _ = runner(state, jax.random.PRNGKey(0), 0)
    g = jax.grad(loss_fn)(average_params(state.x),
                          (xs.reshape(-1, D), ys.reshape(-1)))
    gn = float(np.sqrt(np.asarray(
        sum(jnp.sum(v ** 2) for v in jax.tree_util.tree_leaves(g)))))
    from repro.core import consensus_error
    return gn, float(consensus_error(state.x))


print("=== utility vs privacy (PORTER-DP, rho = 0.05) ===")
print(f"{'epsilon':>10s} {'phi_m':>10s} {'sigma_p':>10s} {'|grad|':>10s}")
for eps in (1.0, 0.1, 0.01):
    sig = calibrate_sigma(1.0, STEPS, m, eps, 1e-3)
    gn, _ = run_sweep("dp", 0.05, sig)
    print(f"{eps:>10g} {phi_m(D, m, eps, 1e-3):>10.4f} {sig:>10.4f} "
          f"{gn:>10.4f}")

print("\n=== compression cost (PORTER-GC, no noise) ===")
print(f"{'rho':>10s} {'|grad(avg)|':>12s} {'consensus':>12s}")
print("(The average iterate is gossip-invariant -- v-bar tracks g-bar "
      "exactly -- so rho's cost shows in the consensus error, the theory's "
      "Lyapunov term.)")
for rho in (1.0, 0.25, 0.05, 0.01):
    gn, cons = run_sweep("gc", rho, 0.0)
    print(f"{rho:>10g} {gn:>12.4f} {cons:>12.3e}")

print("\nBoth axes show the paper's monotone trade-offs: more privacy "
      "(smaller eps) costs utility; more compression (smaller rho) costs "
      "consensus -- and neither breaks convergence.")
