"""Batched serving example: prefill a prompt batch and greedy-decode from a
hybrid (Mamba2 + shared attention) model -- the cache machinery exercised by
the decode_32k / long_500k dry-run shapes, at CPU scale.

    PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch.serve import main

raise SystemExit(main(["--arch", "zamba2-7b", "--smoke", "--batch", "2",
                       "--prompt-len", "32", "--gen", "12"]))
